"""CollaFuse serve runtime — persistent collaborative sampling under
repeated traffic.  Design notes (the serving counterpart of
core/collab.py's vectorized-round notes):

* **Queue → scheduler → cache probe → engine → cache fill → report.**
  One ``ServeRuntime.process(queue)`` call drains a queue of
  SampleRequests: the shape-stable scheduler (serve/scheduler.py)
  buckets requests by cut depth and chunks them into waves; each wave is
  planned (core/sample_plan.plan_requests) with a cache probe per unique
  (y, t_ζ, stride) group — hits inject their stored handoff x̂_{t_ζ} and
  skip the server phase PHYSICALLY (zero model calls, the scanned-group
  axis holds misses only); the padded plan runs as one jitted engine
  call (core/sampler.make_sample_engine); fresh handoffs are inserted
  into the cross-wave LRU cache (serve/prefix_cache.py); the report
  aggregates per-request latency, throughput, hit rate, physical-vs-
  logical model calls and recompiles.
* **Stable keying is the load-bearing invariant.**  The runtime holds ONE
  base PRNG key for its lifetime (``rotate_key`` swaps it deliberately —
  see below); randomness is addressed, never chained:
  a group's server noise depends only on (base key, a content-derived
  seed — sample_plan.stable_group_seed, a digest of the (y, t_ζ, stride)
  identity) and a request's client noise only on (base key, its arrival
  id).  Consequences, each pinned by tests/test_serve_runtime.py: a
  cached handoff is bitwise-valid in any later wave (warm-vs-cold
  equality); re-submitting a request draws FRESH samples (new arrival
  id) while still hitting the cached prefix; and the scheduler's
  bucketing/padding choices cannot perturb outputs (policy invariance,
  padding invariance) — so batching, caching, and bucketing are pure
  performance knobs, never semantics.
* **Shape stability ⇒ bounded compiles.**  Waves of a bucket share step
  geometry; pad_plan pads the request axis to max_wave and the scan/
  inject group axes to power-of-two tiers with inert all-masked rows.
  Steady repeated traffic converges to ONE signature per bucket — with
  every prefix cached the server scan's step axis is LENGTH ZERO, the
  shape-level proof that the server phase disappears.  A Python-side
  trace counter on the jitted engine (incremented only when jit
  re-traces) is the recompile guard the CI smoke asserts on.
* **Accounting: physical vs logical.**  ``server_calls_saved_by_dedup``
  and ``..._by_cache`` count LOGICAL savings; ``padded_model_calls``
  counts the PHYSICAL padding overhead the engine still executes
  (masked steps run their model call and discard it).  Reporting both is
  what shows the scheduler actually reclaiming the waste instead of
  hiding it (benchmarks/collab_serve_runtime.py old/new columns).
* **Sharding.**  The runtime itself is mesh-agnostic (single-process
  CPU serves identically); for mesh runs, sharding/specs carries the
  placement rules for every serve operand — plan tables
  (sample_plan_specs/shard_sample_plan), injected handoffs
  (inject_specs/shard_inject: lead group axis over "clients", request
  batch over "data"), and cached entries (handoff_spec: a single
  (B, ...) x̂_{t_ζ} with batch over "data") — exercised with the engine
  on the ("clients","data") mesh in tests/test_sharding.py.
* **Pipelined waves (no wave barrier).**  The engine's two masked scans
  are built as SEPARATELY jittable stages (make_sample_engine(split=
  True)); each wave dispatches server stage then client stage and — in
  ``pipeline=True`` mode — does NOT block: jax's async dispatch lets
  wave i+1's host work (scheduling, planning, cache probes, the
  ``straggle_s`` stall that models slow request arrival/IO) and wave
  i+1's server scan proceed while wave i's client scan still runs on
  the accelerator.  A double-buffered in-flight window (at most TWO
  waves outstanding) bounds device memory; the oldest wave retires
  (blocks, scatters outputs) when the window is full or the queue
  drains.  Cache fills store the handoff FUTURE at exactly the same
  point in the wave sequence as the sequential loop, so probes, hits,
  physical calls, and outputs are all bitwise identical between
  ``pipeline=True`` and ``pipeline=False`` (differential-tested) —
  pipelining, like batching and caching, is a pure performance knob.
* **Continuous admission (PR 7): ``policy="continuous"``.**  process()'s
  wave list is fixed at call time — a request that misses the call waits
  for the entire queue to drain (head-of-line blocking at the queue
  boundary).  The continuous policy moves admission to WAVE boundaries:
  ``submit()`` appends tickets to per-bucket pending deques,
  ``poll()`` forms and dispatches a wave (scheduler.admit — up to
  max_wave requests popped from the bucket whose head has waited
  longest) whenever the double-buffered in-flight window has a free
  slot, and ``drain()`` runs poll to completion.  ``process()`` on a
  continuous runtime is just submit + drain, so the three are one code
  path.  Admission timing is a pure performance knob like bucketing and
  caching: seeds are content-/arrival-stable and partially-refilled
  waves pad to the exact same tier menu, so continuous output is
  BITWISE equal to depth-bucketed output for the same arrival order,
  with zero new steady-state signatures (pinned by tests and the CI
  smoke; tail latency measured by the Poisson open-loop columns in
  benchmarks/collab_serve_runtime.py).
* **Per-request SLO accounting.**  Every request gets a RequestTicket
  carrying four absolute timestamps: ``t_enqueue`` (entered the runtime
  — submit()/process() call, or the caller-supplied open-loop arrival
  time ``enqueue_t``), ``t_admit`` (left pending, bound into a wave
  being planned), ``t_dispatch`` (its wave's engine stages dispatched),
  ``t_retire`` (its output OBSERVED ready — see the gauge note below).
  The report aggregates latency (retire − enqueue) p50/p95/p99,
  admission wait (admit − enqueue) percentiles, and deadline misses
  against an optional per-request ``slo_s`` (SampleRequest.slo_s, or a
  per-call default); ``per_request`` carries the raw rows.  SLO values
  never steer scheduling — they are accounting only, so adding or
  changing deadlines cannot perturb outputs.

  **Latency gauge semantics (audited, PR 7):** recorded latency is
  enqueue → *observed completion*.  Retirement uses a per-wave ready
  probe (``jax.Array.is_ready``), checked opportunistically before each
  wave's planning, during ``straggle_s`` stalls, and on every poll — so
  in pipelined mode a wave's latency no longer inflates by however long
  the retirement policy left the finished result sitting in the
  in-flight window (the pre-PR-7 behavior conflated device time with
  retirement-policy delay; sequential-vs-pipelined latency semantics
  are pinned by test).  The residual overestimate is bounded by one
  probe interval (~1 ms during stalls, one host planning step
  otherwise), and it is an overestimate only — the gauge never reports
  a request faster than it was.

* **Observability (obs tentpole).**  Reports are DERIVED VIEWS over the
  shared metrics registry (repro.obs.metrics): every accumulator the
  old ``_Frame.acc`` dict and ``CacheStats`` deltas hand-maintained is
  now a typed Counter (frame = snapshot/diff), latency percentiles run
  through frame-windowed Histograms with the exact pre-obs float64
  ``np.percentile`` arithmetic, ``cache_entries``/``cache_bytes`` are
  callback Gauges, and the jit trace counter is the shared
  ``RecompileGuard``.  The delta-vs-gauge taxonomy this module's
  ``_empty_report`` used to document in prose is ENFORCED: every report
  key is classified in the registry (``_SERVE_REPORT_SCHEMA``) and
  tests/test_obs.py fails on an unclassified or shape-drifting key.
  With an active ObsConfig, each wave opens a span decomposed into
  straggle_stall / plan / cache_probe / server_scan / client_scan
  children; the wave span closes at OBSERVED completion (the same
  ready-probe gauge as ticket latency, carrying ``device_wait_s``) and
  is attributed to the report frame it RETIRES in, exactly like the
  ticket percentiles.  The obs contract: disabled (default) is
  structurally inert — NullTracer singleton, zero span allocations, no
  sink IO, reports and samples bitwise-identical to the pre-obs
  runtime; enabled never perturbs outputs — samples bitwise-identical
  to the disabled run with ZERO new jit signatures (pinned by
  tests/test_obs.py and the collab_serve --smoke obs pass).

Reproducibility contract: the serve path is SYNCHRONOUS and bitwise —
every mode of this runtime (pipelined or sequential, any scheduler
policy incl. continuous admission, cache on or off, SLOs tracked or
not, observability on or off) produces bitwise-identical samples for
the same base key and arrival order; the async/staleness relaxation
lives only in train/runtime.py's aggregation, never here.

Remaining open (ROADMAP): a pmap/multi-host request axis,
host-offloaded cache tiers, deeper in-flight windows than the
double-buffered pair when device memory allows.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sample_plan import (GroupKey, SamplePlan, SampleRequest,
                                    call_accounting, pad_plan,
                                    plan_requests, stable_group_seed)
from repro.core.sampler import check_engine_plan, make_sample_engine
from repro.core.schedules import DiffusionSchedule
from repro.obs import DELTA, GAUGE, ObsConfig, RecompileGuard, Telemetry
from repro.obs.metrics import Histogram
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import WaveBucket, WaveScheduler

# Delta-vs-gauge classification of every serve report key (the taxonomy
# _empty_report documents, now enforced by the registry + conformance
# test).  DELTA keys describe the report frame only (summing frames is
# meaningful); GAUGE keys are absolute resident state at report time.
_SERVE_REPORT_SCHEMA = {
    "requests": DELTA, "waves": DELTA, "buckets": DELTA, "wall_s": DELTA,
    "req_per_s": DELTA, "samples_per_s": DELTA,
    "latency_p50_s": DELTA, "latency_p95_s": DELTA, "latency_p99_s": DELTA,
    "admit_wait_p50_s": DELTA, "admit_wait_p95_s": DELTA,
    "slo_tracked": DELTA, "slo_misses": DELTA, "slo_miss_rate": DELTA,
    "per_request": DELTA,
    "server_calls_physical": DELTA, "server_calls_logical": DELTA,
    "client_calls_physical": DELTA, "client_calls_logical": DELTA,
    "padded_model_calls": DELTA,
    "server_calls_saved_by_dedup": DELTA,
    "server_calls_saved_by_cache": DELTA,
    "requests_from_cache": DELTA, "engine_traces": DELTA,
    "signatures_per_bucket": DELTA, "max_signatures_per_bucket": DELTA,
    "cache_hits": DELTA, "cache_misses": DELTA, "cache_hit_rate": DELTA,
    "cache_insertions": DELTA, "cache_evictions": DELTA,
    "cache_rejected": DELTA,
    "cache_entries": GAUGE, "cache_bytes": GAUGE,
}


def _key_fingerprint(key) -> bytes:
    """Stable bytes of a PRNG key (raw uint32 or typed), for cache keys."""
    try:
        data = jax.random.key_data(key)
    except TypeError:          # raw uint32 key on older jax
        data = key
    return np.asarray(data).tobytes()


def _is_ready(x) -> bool:
    """Non-blocking readiness probe; conservatively False when the array
    type predates jax.Array.is_ready (latency then degrades to the old
    retire-time gauge — an overestimate, never an underestimate)."""
    try:
        return bool(x.is_ready())
    except AttributeError:
        return False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    T: int
    image_shape: Tuple[int, ...]          # per-sample trailing (H, W, C)
    max_wave: int = 8
    policy: str = "depth"    # "depth" | "fifo" (PR-3 baseline) |
    #                          "continuous" (admission at wave boundaries)
    server_stride: int = 1                # >1 ⇒ strided DDIM server phase
    adjusted: bool = True
    cache: bool = True
    cache_max_bytes: int = 64 << 20
    cache_max_entries: Optional[int] = None
    use_pallas: Optional[bool] = None
    interpret: bool = False
    pipeline: bool = True                 # False ⇒ per-wave barrier baseline
    straggle_s: float = 0.0               # host-side stall before each wave


@dataclasses.dataclass
class RequestTicket:
    """Per-request admission + SLO record.  Timestamps are absolute
    ``time.perf_counter()`` seconds; -1.0 marks a stage not reached yet.
    ``rid`` is the runtime-lifetime arrival id — it seeds the request's
    client noise (arrival-stable randomness) AND orders continuous
    admission (scheduler.admit pops oldest-rid-first)."""
    rid: int
    request: SampleRequest
    slo_s: Optional[float] = None
    t_enqueue: float = -1.0
    t_admit: float = -1.0
    t_dispatch: float = -1.0
    t_retire: float = -1.0
    output: Optional[jnp.ndarray] = None
    span_id: Optional[int] = None      # its wave's span (None: obs off)

    @property
    def latency_s(self) -> float:
        return self.t_retire - self.t_enqueue

    @property
    def admit_wait_s(self) -> float:
        return self.t_admit - self.t_enqueue

    @property
    def slo_miss(self) -> bool:
        return self.slo_s is not None and self.latency_s > self.slo_s

    def as_row(self, t0: float) -> Dict:
        """Report row; times relative to the report frame's start (an
        open-loop arrival handed in via ``enqueue_t`` can legitimately
        predate the frame — its ``enqueue_s`` is then negative)."""
        rel = lambda t: t - t0 if t >= 0.0 else -1.0
        return {"rid": self.rid, "client": self.request.client,
                "t_cut": self.request.t_cut,
                "enqueue_s": self.t_enqueue - t0,
                "admit_s": rel(self.t_admit),
                "dispatch_s": rel(self.t_dispatch),
                "retire_s": rel(self.t_retire),
                "latency_s": self.latency_s,
                "admit_wait_s": self.admit_wait_s,
                "slo_s": self.slo_s, "slo_miss": self.slo_miss,
                "span_id": self.span_id}


class _Frame:
    """One reporting interval: a registry SNAPSHOT plus the frame's
    retired-ticket population and signature-set detail.  process() opens
    and closes a frame per call; poll-driven serving opens one with
    start_report() and closes it with finish_report() whenever a report
    is wanted — tickets retired during the frame are the frame's
    population (their enqueue may predate it; latency stays honest
    because timestamps are absolute).  Every numeric delta the old
    hand-maintained accumulators tracked is now a counter movement
    between this snapshot and report time."""

    def __init__(self, registry, clock):
        self.t0 = clock()
        self.snap = registry.snapshot()
        self.sigs: Dict[str, set] = {}
        self.retired: List[RequestTicket] = []


class ServeRuntime:
    """The persistent serving loop.  Construct once, ``process`` queues
    (or ``submit``/``poll`` a continuous stream) forever; the cache, seed
    registries, and compiled signatures persist across calls (that
    persistence IS the subsystem)."""

    def __init__(self, config: ServeConfig, server_params, client_params,
                 apply_fn, sched: DiffusionSchedule, key,
                 obs=None):
        if sched.T != config.T:
            raise ValueError(f"schedule T {sched.T} != config T {config.T}")
        self.config = config
        self.server_params = server_params
        self.client_params = client_params
        self.n_clients = jax.tree.leaves(client_params)[0].shape[0]
        self.sched = sched
        self.scheduler = WaveScheduler(config.max_wave, config.policy,
                                       stride=config.server_stride)
        # -- observability: registry (always live — it IS the report
        # mechanism), tracer + sinks (only when an ObsConfig is active)
        self._obs = obs if isinstance(obs, Telemetry) \
            else Telemetry(obs if isinstance(obs, ObsConfig) else None)
        self._clock = self._obs.clock
        self.registry = self._obs.registry
        self.registry.declare_all(_SERVE_REPORT_SCHEMA)
        self._c = {name: self.registry.counter(name) for name in (
            "waves", "n_samples", "requests_retired",
            "server_calls_physical", "server_calls_logical",
            "client_calls_physical", "client_calls_logical",
            "padded_model_calls", "server_calls_saved_by_dedup",
            "server_calls_saved_by_cache", "requests_from_cache")}
        self._hist_latency = self.registry.histogram("latency_s")
        self._hist_wait = self.registry.histogram("admit_wait_s")
        self.cache = PrefixCache(config.cache_max_bytes,
                                 config.cache_max_entries) \
            if config.cache else None
        if self.cache is not None:
            self.cache.bind_instruments(self.registry)
        self.scheduler.bind_instruments(self.registry)
        self._key = key
        self._key_fp = _key_fingerprint(key)
        self._next_rid = 0
        # continuous-admission state: per-bucket pending tickets and the
        # (shared) double-buffered in-flight window (each entry carries
        # its wave span — None while obs is disabled)
        self._pending: "OrderedDict[WaveBucket, Deque[RequestTicket]]" = \
            OrderedDict()
        self._inflight: "Deque[Tuple[jnp.ndarray, Tuple[RequestTicket, ...], object]]" \
            = deque()
        self._frame: Optional[_Frame] = None

        raw_server, raw_client = make_sample_engine(
            sched, apply_fn, config.image_shape,
            use_pallas=config.use_pallas, interpret=config.interpret,
            jit=False, server_ddim=config.server_stride > 1, split=True)

        # the shared RecompileGuard (obs/metrics.py): stage bodies run
        # only when jit (re-)traces — a new table signature — so the
        # guard's counter is the compile guard the smoke asserts on
        # (cache hits on compiled signatures skip it).  Cold traffic
        # traces TWO stages per signature; steady-state traces zero.
        self._guard = RecompileGuard(self.registry.counter("engine_traces"))
        self._server_stage = jax.jit(self._guard.wrap(raw_server))
        self._client_stage = jax.jit(self._guard.wrap(raw_client))
        self._obs.meta(runtime="serve", policy=config.policy,
                       max_wave=config.max_wave, T=config.T,
                       cache=config.cache, pipeline=config.pipeline)

    @property
    def traces(self) -> int:
        """Lifetime engine re-trace (XLA compile) count — the shared
        RecompileGuard's counter."""
        return self._guard.count

    @property
    def obs(self) -> Telemetry:
        """The runtime's telemetry bundle (registry + tracer + sinks).
        Long-lived drivers call ``obs.close()`` at shutdown to flush the
        JSONL stream / Perfetto trace / profiler session."""
        return self._obs

    # -- stable identities -------------------------------------------------
    # Server-noise seeds are sample_plan.stable_group_seed — a digest of
    # the (y, t_ζ, stride) content, so the same prefix gets the same
    # trajectory in every wave, runtime, and scheduler policy.  The cache
    # key appends the seed and base-key fingerprint: the (y, t_ζ, key
    # schedule, stride) identity of the stored x̂_{t_ζ}.
    def _cache_key(self, gk: GroupKey):
        return (gk, stable_group_seed(gk), self._key_fp)

    def _lookup(self, gk: GroupKey):
        return self.cache.lookup(self._cache_key(gk))

    def rotate_key(self, key) -> None:
        """Key rotation for long-lived deployments (the PR-4 cache note):
        swap the base PRNG key and start a fresh cache epoch.  Every
        resident entry is addressed by the OLD key fingerprint and could
        never serve a hit again, so they are dropped via
        PrefixCache.clear() — counted as a clear epoch, not as evictions.
        Refused while requests are pending or in flight (their seeds were
        drawn under the old key) and while a report frame is open (the
        frame's cache-delta baseline belongs to the old epoch)."""
        if self.busy:
            raise RuntimeError("rotate_key with requests pending/in flight")
        if self._frame is not None:
            raise RuntimeError("rotate_key inside an open report frame; "
                               "finish_report() first")
        self._key = key
        self._key_fp = _key_fingerprint(key)
        if self.cache is not None:
            self.cache.clear()

    def rotate_for_epoch(self, epoch: int, base_key) -> bool:
        """DP-epoch-tied key rotation (the PR-4 note, closed by PR 9):
        hook this as the train runtime's ``on_dp_epoch`` callback and the
        serve cache turns over its key schedule at EXACTLY the DP release
        boundary — cached x̂_{t_ζ} prefixes computed under the
        pre-release nets never outlive the privacy epoch they were drawn
        in.  The rotated key is the ADDRESSED ``fold_in(base_key,
        epoch)`` (never chained off the previous rotation), and the call
        is IDEMPOTENT per epoch: replaying a round after a checkpoint
        resume re-fires the callback without clearing the cache twice.
        Returns True when a rotation actually happened."""
        if epoch < 0:
            raise ValueError(f"epoch must be >= 0, got {epoch}")
        if getattr(self, "_rotated_epoch", None) == int(epoch):
            return False
        self.rotate_key(jax.random.fold_in(base_key, int(epoch)))
        self._rotated_epoch = int(epoch)
        return True

    # -- reporting ---------------------------------------------------------
    def _empty_report(self) -> Dict:
        """Zeroed report with the FULL key set — idle ticks must not
        change the report shape consumers sum over.

        Cache field semantics (audited, PR 6): every ``cache_*`` field
        except the last two is a DELTA for this ``process`` call /
        report frame — hits/misses/hit_rate/insertions/evictions/
        rejected all reset to zero per frame, so summing reports across
        frames is meaningful.  ``cache_entries`` and ``cache_bytes`` are
        GAUGES — absolute resident state at report time (an idle tick
        reports the current occupancy, not zero); never sum them.

        Latency field semantics (PR 7): ``latency_*``/``admit_wait_*``
        are percentiles over the requests RETIRED in the frame, from the
        ticket timestamps (enqueue → observed-ready; see module notes on
        the ready-probe gauge); an empty frame reports 0.0, never NaN.
        ``slo_*`` count only tickets that carried a deadline;
        ``per_request`` holds the raw ticket rows (a list — inspect it,
        don't sum it)."""
        report = {
            "requests": 0, "waves": 0, "buckets": 0, "wall_s": 0.0,
            "req_per_s": 0.0, "samples_per_s": 0.0,
            "latency_p50_s": 0.0, "latency_p95_s": 0.0,
            "latency_p99_s": 0.0,
            "admit_wait_p50_s": 0.0, "admit_wait_p95_s": 0.0,
            "slo_tracked": 0, "slo_misses": 0, "slo_miss_rate": 0.0,
            "per_request": [],
            "server_calls_physical": 0, "server_calls_logical": 0,
            "client_calls_physical": 0, "client_calls_logical": 0,
            "padded_model_calls": 0,
            "server_calls_saved_by_dedup": 0,
            "server_calls_saved_by_cache": 0,
            "requests_from_cache": 0, "engine_traces": 0,
            "signatures_per_bucket": {}, "max_signatures_per_bucket": 0,
        }
        if self.cache is not None:
            report.update({
                # deltas (per-frame)
                "cache_hits": 0, "cache_misses": 0, "cache_hit_rate": 0.0,
                "cache_insertions": 0, "cache_evictions": 0,
                "cache_rejected": 0,
                # gauges (absolute resident state)
                "cache_entries": len(self.cache),
                "cache_bytes": self.cache.stats.bytes_in_use,
            })
        return report

    def start_report(self) -> None:
        """Open a fresh accounting frame.  process() does this per call;
        poll-driven serving calls it explicitly (submit/poll open one
        lazily if none is open)."""
        self._frame = _Frame(self.registry, self._clock)

    def finish_report(self) -> Dict:
        """Close the open frame and return its report — a DERIVED VIEW
        over the metrics registry: counter deltas against the frame's
        snapshot, percentile windows over the frame's histogram
        observations, gauge reads at close.  Legal while requests are
        still pending/in flight (a long-lived service reports
        periodically): the frame covers what RETIRED during it; in-flight
        work lands in the next frame."""
        f, self._frame = self._frame, None
        if f is None:
            raise RuntimeError("finish_report without start_report")
        reg = self.registry
        d = lambda name: reg.delta(name, f.snap)
        wall = self._clock() - f.t0
        done = f.retired
        lat = reg.window("latency_s", f.snap)
        wait = reg.window("admit_wait_s", f.snap)
        pct = Histogram.percentile
        tracked = [t for t in done if t.slo_s is not None]
        misses = sum(1 for t in tracked if t.slo_miss)
        report = self._empty_report()
        report.update({
            "requests": len(done), "waves": d("waves"),
            "buckets": len(f.sigs), "wall_s": wall,
            "req_per_s": len(done) / wall if wall > 0 else 0.0,
            "samples_per_s": d("n_samples") / wall if wall > 0 else 0.0,
            "latency_p50_s": pct(lat, 50),
            "latency_p95_s": pct(lat, 95),
            "latency_p99_s": pct(lat, 99),
            "admit_wait_p50_s": pct(wait, 50),
            "admit_wait_p95_s": pct(wait, 95),
            "slo_tracked": len(tracked), "slo_misses": misses,
            "slo_miss_rate": misses / len(tracked) if tracked else 0.0,
            "per_request": [t.as_row(f.t0) for t in done],
            "server_calls_physical": d("server_calls_physical"),
            "server_calls_logical": d("server_calls_logical"),
            "client_calls_physical": d("client_calls_physical"),
            "client_calls_logical": d("client_calls_logical"),
            "padded_model_calls": d("padded_model_calls"),
            "server_calls_saved_by_dedup": d("server_calls_saved_by_dedup"),
            "server_calls_saved_by_cache": d("server_calls_saved_by_cache"),
            "requests_from_cache": d("requests_from_cache"),
            "engine_traces": d("engine_traces"),
            "signatures_per_bucket": {b: len(s)
                                      for b, s in f.sigs.items()},
            "max_signatures_per_bucket": max(
                (len(s) for s in f.sigs.values()), default=0),
        })
        if self.cache is not None:
            d_hits, d_miss = d("cache_hits"), d("cache_misses")
            report.update({
                "cache_hits": d_hits, "cache_misses": d_miss,
                "cache_hit_rate": d_hits / (d_hits + d_miss)
                if d_hits + d_miss else 0.0,
                "cache_insertions": d("cache_insertions"),
                "cache_evictions": d("cache_evictions"),
                "cache_rejected": d("cache_rejected"),
                "cache_entries": reg.read_gauge("cache_entries"),
                "cache_bytes": reg.read_gauge("cache_bytes"),
            })
        self._obs.frame_closed(f.snap, extra={
            "wall_s": wall, "requests": len(done),
            "latency_p50_s": report["latency_p50_s"],
            "latency_p95_s": report["latency_p95_s"],
            "latency_p99_s": report["latency_p99_s"]})
        return report

    # -- wave execution (shared by process and poll) -----------------------
    def _stall(self, seconds: float) -> None:
        """Host-side stall (slow arrivals, planning, IO).  Sleeps in
        ~1 ms slices, probing the in-flight window between slices, so a
        wave finishing on-device mid-stall is retired (and its latency
        time-stamped) the moment it is observably done — not after the
        stall plus the next dispatch.  Sleep releases the GIL, so in
        pipeline mode the accelerator keeps chewing the in-flight waves
        underneath it."""
        deadline = self._clock() + seconds
        while True:
            self._reap()
            rem = deadline - self._clock()
            if rem <= 0.0:
                return
            time.sleep(min(rem, 0.001))

    def _reap(self) -> None:
        """Retire every in-flight wave whose result is observably ready
        (oldest first; retirement order is FIFO regardless of probing)."""
        while self._inflight and _is_ready(self._inflight[0][0]):
            self._retire(block=True)       # ready ⇒ returns immediately

    def _retire(self, block: bool = True) -> bool:
        """Retire the oldest in-flight wave: block on (or probe) its
        result, stamp ``t_retire`` at the moment completion is OBSERVED,
        and scatter outputs to tickets.  Returns False if non-blocking
        and the result is not ready (or nothing is in flight)."""
        if not self._inflight:
            return False
        if not block and not _is_ready(self._inflight[0][0]):
            return False
        out, tickets, wspan = self._inflight.popleft()
        tr = self._obs.tracer
        t0w = self._clock()
        with tr.span("retire", parent=wspan, n_requests=len(tickets)):
            jax.block_until_ready(out)
        now = self._clock()
        for j, t in enumerate(tickets):
            t.t_retire = now
            t.output = out[j]
            self._hist_latency.observe(t.latency_s)
            self._hist_wait.observe(t.admit_wait_s)
        self._c["requests_retired"].inc(len(tickets))
        self._frame.retired.extend(tickets)
        tr.end(wspan, device_wait_s=now - t0w)
        return True

    def _dispatch(self, label: str, tickets: List[RequestTicket]) -> None:
        """Plan and dispatch one wave of tickets (all one bucket for
        depth/continuous; one B for fifo).  Stamps admit before planning
        and dispatch after the engine stages are launched; appends the
        un-materialized output (plus its wave span) to the in-flight
        window.  With obs enabled the wave span opens here and closes at
        OBSERVED completion in ``_retire``; its children decompose the
        host-side work (straggle_stall / plan / cache_probe /
        server_scan / client_scan)."""
        cfg = self.config
        tr = self._obs.tracer
        wspan = tr.start("wave", bucket=label,
                         wave=self._c["waves"].value,
                         n_requests=len(tickets),
                         rids=[t.rid for t in tickets])
        self._obs.step()
        if cfg.straggle_s > 0.0:
            with tr.span("straggle_stall", parent=wspan,
                         seconds=cfg.straggle_s):
                self._stall(cfg.straggle_s)
        now = self._clock()
        sid = None if wspan is None else wspan.sid
        for t in tickets:
            t.t_admit = now
            t.span_id = sid
        use_cache = self.cache is not None
        lookup = self._lookup
        if use_cache and tr.enabled:
            # span-per-probe wrapper, installed ONLY when tracing — the
            # disabled path hands plan_requests the raw bound method
            def lookup(gk, _raw=self._lookup, _tr=tr, _w=wspan):
                with _tr.span("cache_probe", parent=_w):
                    return _raw(gk)
        with tr.span("plan", parent=wspan, bucket=label):
            plan = plan_requests(
                [t.request for t in tickets], cfg.T, adjusted=cfg.adjusted,
                n_clients=self.n_clients,
                server_stride=cfg.server_stride,
                group_seed_fn=stable_group_seed,
                # arrival ids grow forever; mask to int31 for the tables
                # (a seed epoch repeats only after ~2.1e9 requests)
                request_seeds=[t.rid & 0x7FFFFFFF for t in tickets],
                lookup_fn=lookup if use_cache else None,
                image_shape=cfg.image_shape if use_cache else None)
            check_engine_plan(cfg.server_stride > 1, plan)
            padded = pad_plan(
                plan,
                n_groups=self.scheduler.group_tier(plan.n_groups),
                n_requests=self.scheduler.max_wave,
                n_inject=self.scheduler.inject_tier(plan.n_hits)
                if plan.inject is not None else None)
        with tr.span("server_scan", parent=wspan, n_groups=plan.n_groups):
            handoff = self._server_stage(self.server_params, self._key,
                                         padded.tables)
            if use_cache:
                for g in range(plan.n_groups):
                    # zero-step (ICM) prefixes are uncacheable by design;
                    # don't churn the rejected counter every wave.  The
                    # inserted handoff row may still be an un-materialized
                    # future — size/dtype come from the aval, and a later
                    # wave's hit just chains on the device computation —
                    # so this fill point matches the sequential loop's
                    # exactly and cache behavior stays bitwise identical.
                    if plan.group_steps[g] > 0:
                        self.cache.insert(
                            self._cache_key(plan.group_keys[g]),
                            handoff[g], plan.group_steps[g])
        with tr.span("client_scan", parent=wspan, n_hits=plan.n_hits):
            out = self._client_stage(self.client_params, self._key,
                                     padded.tables, handoff, padded.inject)
        self._inflight.append((out, tuple(tickets), wspan))
        c = self._c
        for k_, v in call_accounting(padded).items():
            c[k_].inc(v)
        c["server_calls_saved_by_dedup"].inc(plan.server_steps_saved)
        c["server_calls_saved_by_cache"].inc(
            plan.server_steps_saved_by_cache)
        rg = np.asarray(plan.tables.request_group)
        c["requests_from_cache"].inc(int((rg >= plan.n_groups).sum()))
        self._frame.sigs.setdefault(label, set()).add(
            plan_signature(padded))
        c["waves"].inc()
        c["n_samples"].inc(
            sum(int(t.request.y.shape[0]) for t in tickets))
        td = self._clock()
        for t in tickets:
            t.t_dispatch = td

    def _make_ticket(self, r: SampleRequest, slo_s: Optional[float],
                     enqueue_t: Optional[float]) -> RequestTicket:
        t = RequestTicket(
            rid=self._next_rid, request=r,
            slo_s=r.slo_s if r.slo_s is not None else slo_s,
            t_enqueue=self._clock() if enqueue_t is None
            else enqueue_t)
        self._next_rid += 1
        return t

    # -- continuous admission (policy="continuous") ------------------------
    @property
    def busy(self) -> bool:
        """True while any request is pending admission or in flight."""
        return bool(self._inflight) or \
            any(len(q) > 0 for q in self._pending.values())

    def submit(self, requests: Sequence[SampleRequest],
               slo_s: Optional[float] = None,
               enqueue_t: Optional[Sequence[float]] = None
               ) -> List[RequestTicket]:
        """Enqueue requests for continuous admission; returns their
        tickets (outputs land on ``ticket.output`` at retirement).
        ``slo_s`` is the deadline default for requests that don't carry
        their own; ``enqueue_t`` overrides the enqueue timestamps with
        caller-side arrival times (absolute ``time.perf_counter``
        seconds — the open-loop benchmark charges pre-submit queueing to
        the latency gauge this way).  Only the continuous policy admits
        incrementally; depth/fifo admit at queue-drain boundaries
        through process()."""
        if self.config.policy != "continuous":
            raise ValueError(
                f"submit() requires policy='continuous' (got "
                f"{self.config.policy!r}); depth/fifo admit whole queues "
                "via process()")
        if enqueue_t is not None and len(enqueue_t) != len(requests):
            raise ValueError(f"{len(enqueue_t)} enqueue_t for "
                             f"{len(requests)} requests")
        if self._frame is None:
            self.start_report()
        tickets = []
        for i, r in enumerate(requests):
            t = self._make_ticket(
                r, slo_s, None if enqueue_t is None else enqueue_t[i])
            self._pending.setdefault(self.scheduler.bucket_of(r),
                                     deque()).append(t)
            tickets.append(t)
        return tickets

    def poll(self, block: bool = False) -> List[RequestTicket]:
        """One admission turn: retire observably-finished waves, then —
        while the in-flight window has room — form and dispatch waves
        from the pending deques (scheduler.admit).  ``block=True``
        additionally forces the oldest in-flight wave to retire, which
        guarantees progress (drain() is poll(block=True) to emptiness).
        Returns the tickets retired during this call."""
        if self._frame is None:
            self.start_report()
        done0 = len(self._frame.retired)
        self._reap()
        window = 2 if self.config.pipeline else 1
        while len(self._inflight) < window:
            admitted = self.scheduler.admit(self._pending)
            if admitted is None:
                break
            bucket, tickets = admitted
            self._dispatch(bucket.label(), list(tickets))
            self._reap()
        if block and self._inflight:
            self._retire(block=True)
        return self._frame.retired[done0:]

    def drain(self) -> List[RequestTicket]:
        """Poll until nothing is pending or in flight; returns all
        tickets retired along the way."""
        done: List[RequestTicket] = []
        while self.busy:
            done.extend(self.poll(block=True))
        return done

    # -- the loop ----------------------------------------------------------
    def process(self, queue: Sequence[SampleRequest],
                slo_s: Optional[float] = None,
                enqueue_t: Optional[Sequence[float]] = None
                ) -> Tuple[List[jnp.ndarray], Dict]:
        """Drain ``queue``; returns (outputs in arrival order — one
        (B, *image_shape) array per request — and the serve report for
        THIS call: latency/SLO accounting, throughput, logical savings,
        physical padding overhead, cache deltas, recompiles and
        signatures per bucket).

        ``config.pipeline=True`` keeps up to two waves in flight
        (dispatch wave i+1 while wave i still runs — see module notes);
        ``False`` is the barrier-per-wave baseline.  Under
        ``policy="continuous"`` the call is submit + drain over the
        incremental admission loop.  Outputs and cache behavior are
        bitwise identical across all of it; ``slo_s``/``enqueue_t`` (see
        submit()) only affect accounting."""
        if self.busy:
            raise RuntimeError("process() while continuous requests are "
                               "pending/in flight; drain() first")
        if self._frame is not None:
            raise RuntimeError("process() inside an open report frame; "
                               "finish_report() first")
        if not queue:
            return [], self._empty_report()
        if enqueue_t is not None and len(enqueue_t) != len(queue):
            raise ValueError(f"{len(enqueue_t)} enqueue_t for "
                             f"{len(queue)} requests")
        self.start_report()
        if self.config.policy == "continuous":
            tickets = self.submit(queue, slo_s=slo_s, enqueue_t=enqueue_t)
            self.drain()
        else:
            tickets = [self._make_ticket(
                r, slo_s, None if enqueue_t is None else enqueue_t[i])
                for i, r in enumerate(queue)]
            for wave in self.scheduler.waves(queue):
                self._reap()
                self._dispatch(wave.bucket.label(),
                               [tickets[qi] for qi in wave.queue_idx])
                while len(self._inflight) > \
                        (1 if self.config.pipeline else 0):
                    self._retire(block=True)
            while self._inflight:
                self._retire(block=True)
        outputs = [t.output for t in tickets]
        return outputs, self.finish_report()


def plan_signature(plan: SamplePlan) -> tuple:
    """Shape signature of a (padded) plan — what jit keys compiles on."""
    return tuple(a.shape for a in plan.tables) + \
        (tuple(a.shape for a in plan.inject)
         if plan.inject is not None else ())
