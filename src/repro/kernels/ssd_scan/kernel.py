"""Pallas TPU kernel: Mamba2 SSD chunked scan [arXiv:2405.21060].

Grid (B, H, n_chunks) with the chunk dimension innermost and sequential:
the (P, N) recurrent state lives in a VMEM scratch buffer that persists
across the chunk iterations of one (batch, head) program — the classic
linear-attention Pallas pattern. Per chunk, the kernel fuses:

  intra-chunk:  y += (C·Bᵀ ⊙ tril-decay) @ (dt·x)        (q×q MXU matmul)
  inter-chunk:  y += (C ⊙ e^L) @ stateᵀ
  state update: state ← e^{L_q}·state + (B ⊙ decay_to_end ⊙ dt·x)

keeping L (the per-step log-decay cumsum) in registers — the jnp reference
materializes the (b, nc, q, q, h) decay tensor in HBM, which is exactly the
memory-roofline term this kernel removes (see EXPERIMENTS §Perf).

Chunk length q and head dim p should be 128-multiples on real TPU for MXU
alignment; correctness is shape-agnostic and validated in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fs_ref, state_ref,
                *, q, p, n):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros((p, n), jnp.float32)

    x = x_ref[0, 0, 0].astype(jnp.float32)          # (q, p)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (q,)
    A = a_ref[0, 0]                                  # scalar (negative)
    B = b_ref[0, 0].astype(jnp.float32)              # (q, n)
    C = c_ref[0, 0].astype(jnp.float32)              # (q, n)

    dA = dt * A
    L = jnp.cumsum(dA)                               # (q,)
    dtx = x * dt[:, None]                            # (q, p)

    # intra-chunk
    diff = L[:, None] - L[None, :]
    causal = jnp.tril(jnp.ones((q, q), jnp.bool_))
    decay = jnp.where(causal, jnp.exp(diff), 0.0)
    CB = C @ B.T                                     # (q, q)
    y = (CB * decay) @ dtx                           # (q, p)

    # inter-chunk
    state = state_ref[...]                           # (p, n)
    y = y + (C * jnp.exp(L)[:, None]) @ state.T      # (q, p)

    # state update
    # S_c = Σ_s decay_to_end_s · dt_s · x_s ⊗ B_s  (dtx already carries dt)
    decay_to_end = jnp.exp(L[-1] - L)                # (q,)
    S_c = dtx.T @ (B * decay_to_end[:, None])
    state_ref[...] = jnp.exp(L[-1]) * state + S_c

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    fs_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, B, C, chunk: int, interpret: bool = False):
    """Same contract as models.ssm.ssd_chunked (zero initial state).

    x: (b, s, h, p); dt: (b, s, h); A: (h,); B/C: (b, s, n).
    Returns (y (b, s, h, p), final_state (b, h, p, n))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, B, C = zpad(x), zpad(dt), zpad(B), zpad(C)
    sp = s + pad
    nc, q = sp // chunk, chunk

    # blocked layouts: head-major for per-(b,h) sequential chunk walk
    xb = x.reshape(b, nc, q, h, p).transpose(0, 3, 1, 2, 4)   # (b,h,nc,q,p)
    dtb = dt.reshape(b, nc, q, h).transpose(0, 3, 1, 2)       # (b,h,nc,q)
    Bb = B.reshape(b, nc, q, n)
    Cb = C.reshape(b, nc, q, n)
    Ab = A.reshape(h, 1).astype(jnp.float32)

    grid = (b, h, nc)
    kernel = functools.partial(_ssd_kernel, q=q, p=p, n=n)
    y, fs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1), lambda i, j, c: (j, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j, c: (i, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, q, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xb, dtb, Ab, Bb, Cb)
    y = y.transpose(0, 2, 3, 1, 4).reshape(b, sp, h, p)[:, :s]
    return y, fs
