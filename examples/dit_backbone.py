"""CollaFuse with an assigned-architecture backbone (DiT bridge).

    PYTHONPATH=src python examples/dit_backbone.py [arch]

Runs the same split protocol with a reduced mamba2-2.7b (default) or any
other assigned arch id as the denoiser — the paper's technique as a
first-class feature of the framework (DESIGN.md §5).
"""
import sys

import jax

from repro.core.collab import CollabConfig, sample_for_client, setup, train_round
from repro.data.synthetic import SyntheticConfig, batches, make_client_datasets
from repro.eval.fd_proxy import fd_proxy

arch = sys.argv[1] if len(sys.argv) > 1 else "mamba2-2.7b"
key = jax.random.PRNGKey(0)
ccfg = CollabConfig(n_clients=2, T=30, t_cut=8, image_size=8, batch_size=4,
                    n_classes=8, denoiser=arch, dit_patch=2)
dcfg = SyntheticConfig(image_size=8, n_attrs=8)
data = make_client_datasets(key, dcfg, 2, 128, non_iid=True)

state, step_fn, apply_fn = setup(key, ccfg)
per_client = [list(batches(x, y, 4, key))[:10] for x, y in data]
metrics = train_round(state, step_fn, per_client, key)
print(f"backbone={arch}: {metrics[0]}")
samp = sample_for_client(state, 0, key, data[0][1][:16], ccfg, apply_fn)
print("samples:", samp.shape, "FD:",
      round(fd_proxy(data[0][0][:64], samp), 3))
