"""Round planning: cohort → one shape-stable padded stack per tier.

The PR-2 masked engine already makes zero-padding inert WITHIN a fixed
client stack (row/batch masking).  This module extends the same trick to
the CLIENT AXIS itself: a round's cohort — whatever the participation
sampler produced — is seated into a stack padded to the next
power-of-two participation TIER, with the pad slots fully masked.  Batch
count and batch size are pinned by the runtime config, so the compiled
signature of a round depends on NOTHING but the tier: drifting cohort
sizes {3, 5, 2, 4, …} converge onto the tier menu {4, 8} instead of one
XLA compile per size (the jit trace-counter guard in train/runtime.py
asserts exactly this).

Everything in a plan is derived from addressed draws: member m's batches
this round are its own dataset shuffled by
``fold_in(fold_in(fold_in(base, TAG_DATA), round), uid)``, and the pad
slots repeat member 0's uid/data — harmless, because their mask is
all-zero and the engine's where-skipped AdamW plus identity-keyed
randomness make a masked slot a bitwise no-op for every real slot.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import batches
from repro.train.participation import TAG_DATA
from repro.train.registry import ClientRegistry


def participation_tier(n: int, cap: Optional[int] = None) -> int:
    """Next power of two >= max(n, 1), optionally capped — the cohort
    axis's fixed shape menu (the client-axis sibling of
    serve/scheduler.tier).  Like its sibling, the cap is rounded UP to
    a power of two before applying: a raw non-pow2 cap would leak a
    non-pow2 tier into the menu and defeat the finite-signature
    guarantee the runtime's trace-counter guard asserts."""
    t = 1
    while t < n:
        t *= 2
    if cap is None:
        return t
    c = 1
    while c < max(cap, 1):
        c *= 2
    return min(t, c)


@dataclasses.dataclass
class RoundPlan:
    """One round's engine inputs: fixed-shape stacks + the identity
    vector.  ``cohort`` lists the real member uids (slot order);
    slots ``len(cohort)..tier-1`` are all-masked padding."""
    round_idx: int
    cohort: List[int]
    tier: int
    xs: jnp.ndarray           # (n_batches, tier, B, H, W, C)
    ys: jnp.ndarray           # (n_batches, tier, B, n_classes)
    mask: jnp.ndarray         # (n_batches, tier, B) 0/1 validity
    uids: jnp.ndarray         # (tier,) int32 registry identities
    drops: Dict[int, int]     # uid -> first masked batch slot (mid-round)

    @property
    def real_samples(self) -> int:
        return int(np.asarray(self.mask).sum())

    @property
    def padded_cells(self) -> int:
        return int(self.mask.size) - self.real_samples

    def signature(self) -> tuple:
        """What jit keys compiles on — shapes only, never values."""
        return (self.xs.shape, self.ys.shape, self.mask.shape,
                self.uids.shape)


def plan_round(registry: ClientRegistry, cohort: Sequence[int],
               round_idx: int, base_key, *, n_batches: int, batch_size: int,
               image_shape, n_classes: int, tier_cap: Optional[int] = None,
               drops: Optional[Dict[int, int]] = None
               ) -> Optional[RoundPlan]:
    """Build the padded stacks for ``cohort``.  Returns None for an empty
    cohort or when no member holds a single sample (the runtime then
    advances the cursor without an engine call).  Each member contributes
    up to ``n_batches`` batches of up to ``batch_size`` rows from its own
    registry data (round-keyed shuffle, trailing partial batch kept);
    shorter members are row/batch-masked exactly like PR-2 raggedness."""
    cohort = list(cohort)
    if not cohort:
        return None
    tier = participation_tier(len(cohort), tier_cap)
    if len(cohort) > tier:
        raise ValueError(f"cohort of {len(cohort)} exceeds tier cap {tier}")
    H, W, C = image_shape
    xs = np.zeros((n_batches, tier, batch_size, H, W, C), np.float32)
    ys = np.zeros((n_batches, tier, batch_size, n_classes), np.float32)
    mask = np.zeros((n_batches, tier, batch_size), np.float32)
    dkey = jax.random.fold_in(base_key, TAG_DATA)
    rkey = jax.random.fold_in(dkey, round_idx)
    drops = drops or {}
    for m, uid in enumerate(cohort):
        rec = registry.get(uid)
        if rec.n_samples == 0:
            continue
        it = batches(rec.x, rec.y, batch_size,
                     key=jax.random.fold_in(rkey, uid), drop_last=False)
        for b, (x, y) in enumerate(it):
            if b >= n_batches:
                break
            n = x.shape[0]
            xs[b, m, :n] = np.asarray(x)
            ys[b, m, :n] = np.asarray(y)
            mask[b, m, :n] = 1.0
        if uid in drops:                  # gone from slot d onward
            mask[drops[uid]:, m, :] = 0.0
    if mask.sum() == 0:
        return None
    pad_uid = cohort[0]
    uid_vec = np.asarray(cohort + [pad_uid] * (tier - len(cohort)), np.int32)
    return RoundPlan(round_idx=round_idx, cohort=cohort, tier=tier,
                     xs=jnp.asarray(xs), ys=jnp.asarray(ys),
                     mask=jnp.asarray(mask), uids=jnp.asarray(uid_vec),
                     drops=dict(drops))
