"""Msgpack-based pytree checkpointing (no orbax offline).

Arrays are serialized as (dtype, shape, raw bytes); the pytree structure is
encoded with string-keyed dicts / lists. Saves are atomic AND durable
(tmp + fsync + rename). CollaFuse drivers persist {server, clients[i],
opt states, step}; the federated training runtime (repro.train) persists
its full resumable state {params, opt states, registry, cohort cursor,
base RNG key, EMA}.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy
import msgpack
import numpy as np

_ARR = "__arr__"


def _pack(obj):
    if isinstance(obj, (jnp.ndarray, np.ndarray, np.generic)):
        # np.generic: numpy SCALARS (np.float32(x), np.bool_(True), …) —
        # easy to produce from eager reductions; packed as 0-d arrays so
        # their dtype survives the trip (as python floats it would not).
        a = np.asarray(obj)
        # dtype by NAME ("bfloat16"): ml_dtypes registers these with numpy,
        # while the .str form ("|V2") round-trips as raw void.
        return {_ARR: True, "dtype": a.dtype.name, "shape": list(a.shape),
                "data": a.tobytes()}
    if isinstance(obj, dict):
        return {k: _pack(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return {"__list__": [_pack(v) for v in obj],
                "__tuple__": isinstance(obj, tuple)}
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return obj
    raise TypeError(f"unsupported checkpoint leaf: {type(obj)}")


def _unpack(obj):
    if isinstance(obj, dict):
        if obj.get(_ARR):
            a = np.frombuffer(obj["data"], dtype=np.dtype(obj["dtype"]))
            a = a.reshape(obj["shape"])
            j = jnp.asarray(a)
            if j.dtype != a.dtype:
                # jnp.asarray silently downcasts 64-bit leaves when
                # jax_enable_x64 is off — return the (writable) numpy
                # array instead so the round trip never mangles a dtype
                return a.copy()
            return j
        if "__list__" in obj:
            items = [_unpack(v) for v in obj["__list__"]]
            return tuple(items) if obj.get("__tuple__") else items
        return {k: _unpack(v) for k, v in obj.items()}
    return obj


def save(path: str, tree: Any) -> None:
    payload = msgpack.packb(_pack(tree), use_bin_type=True)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
            # fsync BEFORE the atomic rename: rename orders metadata, not
            # data — a crash between rename and writeback could otherwise
            # leave a valid name on truncated bytes (the mid-run-resume
            # contract of the training runtime needs the file durable).
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load(path: str) -> Any:
    with open(path, "rb") as f:
        return _unpack(msgpack.unpackb(f.read(), raw=False))
